"""End-to-end serving smoke: the tier-1 guard for repro/serve.

Drives the real engine on the reduced gemma config — batched
heterogeneous-rank multi-LoRA decode vs the per-request merged-weight
oracle, continuous batching with row recycling, and retrace-free
hot-swap. This is the test that would have caught the PR-1
``TPUCompilerParams`` API drift before it reached main.

The engine defaults to the paged KV cache with chunked prefill
(PR 3), so these tests pin that path; the retained dense ring cache is
covered explicitly (``kv_mode="dense"``), including the wrap-instead-
of-corrupt regression. A paged engine traces exactly twice: once for
the chunked-prefill step, once for the decode step.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.configs.base import LoRAConfig
from repro.models import model as model_lib
from repro.serve import AdapterRegistry, ServeEngine
from repro.serve.oracle import make_demo_adapter, merged_greedy

RANKS = (2, 4, 6, 8)
PROMPT_LEN = 6
STEPS = 10
PAGED_TRACES = 2   # one prefill trace + one decode trace


@pytest.fixture(scope="module")
def setup():
    cfg = get_reduced("gemma-2b")
    key = jax.random.PRNGKey(0)
    params = model_lib.init_params(key, cfg)
    adapters = {
        f"client{i}": make_demo_adapter(jax.random.fold_in(key, 100 + i),
                                        cfg, r)
        for i, r in enumerate(RANKS)}
    prompts = np.asarray(jax.random.randint(
        jax.random.fold_in(key, 3), (8, PROMPT_LEN), 3, cfg.vocab_size))
    return cfg, params, adapters, prompts


def _registry(cfg, adapters):
    reg = AdapterRegistry(cfg, capacity=len(adapters))
    for aid, tree in adapters.items():
        reg.register(aid, tree)
    return reg


def test_batched_heterogeneous_decode_matches_merged_oracle(setup):
    """8 concurrent requests across 4 distinct heterogeneous-rank adapters
    -> greedy tokens identical to per-request merged-weight decoding."""
    cfg, params, adapters, prompts = setup
    engine = ServeEngine(params, cfg, _registry(cfg, adapters),
                         max_batch=8, max_seq=PROMPT_LEN + STEPS)
    uids = [engine.submit(prompts[i], f"client{i % len(RANKS)}",
                          max_new_tokens=STEPS) for i in range(8)]
    outs = engine.run()
    assert engine.trace_count == PAGED_TRACES
    for i, uid in enumerate(uids):
        want = merged_greedy(params, cfg, prompts[i],
                             adapters[f"client{i % len(RANKS)}"], STEPS)
        np.testing.assert_array_equal(outs[uid], want)


def test_mlp_lora_targets_match_merged_oracle(setup):
    """The engine's MLP adapter path (w1/w2/w3 targets) against the same
    merged-weight oracle — attention-only coverage would miss it."""
    cfg, _, _, prompts = setup
    cfg = cfg.with_(lora=LoRAConfig(targets=("q", "v", "w1", "w2", "w3"),
                                    r_max=8))
    key = jax.random.PRNGKey(1)
    params = model_lib.init_params(key, cfg)
    adapters = {f"m{i}": make_demo_adapter(jax.random.fold_in(key, 10 + i),
                                           cfg, r)
                for i, r in enumerate((3, 8))}
    engine = ServeEngine(params, cfg, _registry(cfg, adapters),
                         max_batch=4, max_seq=PROMPT_LEN + STEPS)
    uids = [engine.submit(prompts[i], f"m{i % 2}", max_new_tokens=STEPS)
            for i in range(4)]
    outs = engine.run()
    for i, uid in enumerate(uids):
        want = merged_greedy(params, cfg, prompts[i],
                             adapters[f"m{i % 2}"], STEPS)
        np.testing.assert_array_equal(outs[uid], want)


def test_continuous_batching_recycles_rows(setup):
    """More requests than rows, uneven lengths: finished rows are recycled
    for queued requests, outputs stay correct, nothing retraces."""
    cfg, params, adapters, prompts = setup
    engine = ServeEngine(params, cfg, _registry(cfg, adapters),
                         max_batch=2, max_seq=PROMPT_LEN + STEPS)
    lens = [3, 7, 5, 10, 4]
    uids = [engine.submit(prompts[i], f"client{i % len(RANKS)}",
                          max_new_tokens=lens[i]) for i in range(5)]
    outs = engine.run()
    assert engine.trace_count == PAGED_TRACES
    for i, uid in enumerate(uids):
        want = merged_greedy(params, cfg, prompts[i],
                             adapters[f"client{i % len(RANKS)}"], lens[i])
        np.testing.assert_array_equal(outs[uid], want)


def test_hot_swap_changes_output_without_retrace(setup):
    cfg, params, adapters, prompts = setup
    reg = _registry(cfg, adapters)
    engine = ServeEngine(params, cfg, reg, max_batch=2,
                         max_seq=PROMPT_LEN + STEPS)
    uid = engine.submit(prompts[0], "client3", max_new_tokens=STEPS)
    before = engine.run()[uid]
    traces = engine.trace_count

    swapped = {t: dict(ad, B=ad["B"] + 0.05) for t, ad
               in adapters["client3"].items()}
    reg.register("client3", swapped)
    reg.refresh("client3")
    uid2 = engine.submit(prompts[0], "client3", max_new_tokens=STEPS)
    after = engine.run()[uid2]

    assert engine.trace_count == traces          # zero recompilation
    want = merged_greedy(params, cfg, prompts[0], swapped, STEPS)
    np.testing.assert_array_equal(after, want)   # swap took effect
    assert not np.array_equal(before, after)


def test_requests_are_isolated(setup):
    """A row's tokens don't depend on what else is in the batch: serve the
    same request alone and packed with 7 strangers."""
    cfg, params, adapters, prompts = setup
    reg = _registry(cfg, adapters)
    engine = ServeEngine(params, cfg, reg, max_batch=8,
                         max_seq=PROMPT_LEN + STEPS)
    uid_alone = engine.submit(prompts[0], "client0", max_new_tokens=STEPS)
    alone = engine.run()[uid_alone]
    uids = [engine.submit(prompts[i], f"client{i % len(RANKS)}",
                          max_new_tokens=STEPS) for i in range(8)]
    packed = engine.run()
    np.testing.assert_array_equal(packed[uids[0]], alone)


def test_more_adapters_than_slots_defers_admission(setup):
    """Registry smaller than the working set: requests whose adapter
    cannot be pinned wait in the queue instead of crashing the loop, and
    every request still finishes correctly once slots free up."""
    cfg, params, adapters, prompts = setup
    reg = AdapterRegistry(cfg, capacity=2)
    for aid, tree in adapters.items():
        reg.register(aid, tree)
    engine = ServeEngine(params, cfg, reg, max_batch=4,
                         max_seq=PROMPT_LEN + STEPS)
    uids = [engine.submit(prompts[i], f"client{i}", max_new_tokens=4)
            for i in range(4)]
    outs = engine.run()
    assert reg.evictions >= 1
    for i, uid in enumerate(uids):
        want = merged_greedy(params, cfg, prompts[i],
                             adapters[f"client{i}"], 4)
        np.testing.assert_array_equal(outs[uid], want)


def test_submit_rejections(setup):
    cfg, params, adapters, _ = setup
    engine = ServeEngine(params, cfg, _registry(cfg, adapters),
                         max_batch=2, max_seq=8)
    with pytest.raises(ValueError):
        engine.submit(np.arange(5, dtype=np.int32), "client0",
                      max_new_tokens=8)
    with pytest.raises(KeyError):
        engine.submit(np.arange(2, dtype=np.int32), "nobody",
                      max_new_tokens=2)


# ---------------------------------------------------------------------------
# Paged KV specifics
# ---------------------------------------------------------------------------

def test_paged_matches_dense_and_oracle(setup):
    """The paged engine, the dense fallback, and the merged-weight oracle
    all agree token-for-token on the same traffic."""
    cfg, params, adapters, prompts = setup
    outs = {}
    for mode in ("paged", "dense"):
        engine = ServeEngine(params, cfg, _registry(cfg, adapters),
                             max_batch=4, max_seq=PROMPT_LEN + STEPS,
                             kv_mode=mode, page_size=4, prefill_chunk=4)
        uids = [engine.submit(prompts[i], f"client{i % len(RANKS)}",
                              max_new_tokens=STEPS) for i in range(4)]
        done = engine.run()
        outs[mode] = [done[u] for u in uids]
    for i in range(4):
        want = merged_greedy(params, cfg, prompts[i],
                             adapters[f"client{i % len(RANKS)}"], STEPS)
        np.testing.assert_array_equal(outs["paged"][i], want)
        np.testing.assert_array_equal(outs["dense"][i], want)


def test_paged_oversubscription_defers_and_preempts(setup):
    """A pool with fewer pages than the traffic needs: admission defers,
    decode-time extension preempts, and every request still finishes
    with oracle-exact tokens — with zero retraces throughout."""
    cfg, params, adapters, prompts = setup
    engine = ServeEngine(params, cfg, _registry(cfg, adapters),
                         max_batch=8, max_seq=PROMPT_LEN + STEPS,
                         page_size=4, num_pages=10, prefill_chunk=4)
    uids = [engine.submit(prompts[i], f"client{i % len(RANKS)}",
                          max_new_tokens=STEPS) for i in range(8)]
    outs = engine.run()
    assert engine.deferrals > 0          # pool was actually oversubscribed
    assert engine.trace_count == PAGED_TRACES
    engine.kv.allocator.check()          # no page leaked or double-owned
    assert engine.kv.allocator.free_count == engine.kv.num_pages
    for i, uid in enumerate(uids):
        want = merged_greedy(params, cfg, prompts[i],
                             adapters[f"client{i % len(RANKS)}"], STEPS)
        np.testing.assert_array_equal(outs[uid], want)


def test_paged_admits_beyond_dense_bound(setup):
    """The page pool admits concurrent traffic a dense cache of the same
    memory could not: 4 short requests through a pool whose bytes equal
    a 2-row dense cache."""
    cfg, params, adapters, prompts = setup
    # dense: 2 rows x 16 slots; paged: pool of 8 pages x 4 slots = same
    # token capacity, but spread over 4 concurrent rows.
    engine = ServeEngine(params, cfg, _registry(cfg, adapters),
                         max_batch=4, max_seq=16, page_size=4, num_pages=8,
                         prefill_chunk=4)
    uids = [engine.submit(prompts[i][:4], f"client{i}", max_new_tokens=4)
            for i in range(4)]   # 8 tokens each = 2 pages each
    outs = engine.run()
    assert set(outs) == set(uids)
    assert engine.deferrals == 0         # all 4 admitted concurrently
    for i, uid in enumerate(uids):
        want = merged_greedy(params, cfg, prompts[i][:4],
                             adapters[f"client{i}"], 4)
        np.testing.assert_array_equal(outs[uid], want)


def test_paged_trace_flat_across_page_extensions(setup):
    """Crossing page boundaries (1-token prompt growing 12 tokens across
    3 pages) extends the row's page list without retracing."""
    cfg, params, adapters, prompts = setup
    engine = ServeEngine(params, cfg, _registry(cfg, adapters),
                         max_batch=2, max_seq=16, page_size=4,
                         prefill_chunk=4)
    uid = engine.submit(prompts[0][:2], "client0", max_new_tokens=12)
    outs = engine.run()
    assert engine.trace_count == PAGED_TRACES
    want = merged_greedy(params, cfg, prompts[0][:2], adapters["client0"],
                         12)
    np.testing.assert_array_equal(outs[uid], want)


def test_prefill_chunk_size_does_not_change_tokens(setup):
    """Chunked prefill is an evaluation strategy, not a semantic change:
    any chunk size produces identical greedy tokens."""
    cfg, params, adapters, prompts = setup
    ref_out = None
    for chunk in (1, 3, 4, 16):
        engine = ServeEngine(params, cfg, _registry(cfg, adapters),
                             max_batch=2, max_seq=PROMPT_LEN + STEPS,
                             page_size=4, prefill_chunk=chunk)
        uid = engine.submit(prompts[1], "client1", max_new_tokens=STEPS)
        out = engine.run()[uid]
        if ref_out is None:
            ref_out = out
        else:
            np.testing.assert_array_equal(out, ref_out)
    want = merged_greedy(params, cfg, prompts[1], adapters["client1"],
                         STEPS)
    np.testing.assert_array_equal(ref_out, want)


def test_paged_engine_pallas_kernels_interpret(setup):
    """The TPU code path end-to-end (BGMV + paged_attn decode + flash
    chunked prefill, all in interpret mode): same greedy tokens as the
    merged oracle, including a pool capacity that is not a multiple of
    the flash block size."""
    cfg, params, adapters, prompts = setup
    # 33 pages x 8 slots = 264-token row capacity: NOT a multiple of the
    # 256 default flash block — the prefill path must pick a dividing
    # block size instead of tripping the kernel's tiling assert.
    engine = ServeEngine(params, cfg, _registry(cfg, adapters),
                         max_batch=2, max_seq=264,
                         page_size=8, prefill_chunk=4, use_pallas=True)
    uid = engine.submit(prompts[2], "client2", max_new_tokens=3)
    outs = engine.run()
    want = merged_greedy(params, cfg, prompts[2], adapters["client2"], 3)
    np.testing.assert_array_equal(outs[uid], want)


def test_rows_grouped_by_adapter_slot(setup):
    """Paged dispatches sort batch rows by adapter slot before the BGMV
    gather (the SGMV precondition) — a host-side permutation, so greedy
    tokens are unchanged and the distinct-slot count is surfaced."""
    cfg, params, adapters, prompts = setup
    engine = ServeEngine(params, cfg, _registry(cfg, adapters),
                         max_batch=8, max_seq=PROMPT_LEN + STEPS)
    uids = [engine.submit(prompts[i], f"client{i % len(RANKS)}",
                          max_new_tokens=STEPS) for i in range(8)]
    outs = engine.run()
    # equal-length requests: the last decode dispatch still had all 8
    # rows active across the 4 distinct adapters
    assert engine.bgmv_groups == len(RANKS)
    assert engine.trace_count == PAGED_TRACES    # sorting never retraces
    for i, uid in enumerate(uids):
        want = merged_greedy(params, cfg, prompts[i],
                             adapters[f"client{i % len(RANKS)}"], STEPS)
        np.testing.assert_array_equal(outs[uid], want)


# ---------------------------------------------------------------------------
# Dense-ring fallback regression (the PR-3 satellite bugfix)
# ---------------------------------------------------------------------------

def test_dense_ring_overflow_raises_not_corrupts(setup):
    """A row driven past its ring must fail loudly. The seed engine
    silently wrapped ``pos % slots``, overwriting the oldest live slots
    while the validity mask still reported them current."""
    cfg, params, adapters, prompts = setup
    engine = ServeEngine(params, cfg, _registry(cfg, adapters),
                         max_batch=1, max_seq=8, kv_mode="dense")
    uid = engine.submit(prompts[0][:4], "client0", max_new_tokens=4)
    # bypass submit's guard, as a scheduler bug or future code path might
    engine._queue[0]["max_new"] = 10
    with pytest.raises(RuntimeError, match="ring"):
        engine.run()
    del uid


def test_dense_insert_drops_out_of_range_writes():
    """The traced insert itself fails safe: an out-of-range position
    leaves the cache bit-identical instead of wrapping onto slot 0."""
    from repro.serve.engine import _cache_insert_rows
    lc = {"k": jax.numpy.ones((2, 4, 1, 8)),
          "v": jax.numpy.ones((2, 4, 1, 8)),
          "pos": jax.numpy.zeros((2, 4), jax.numpy.int32)}
    k_new = jax.numpy.full((2, 1, 1, 8), 7.0)
    out = _cache_insert_rows(lc, k_new, k_new,
                             jax.numpy.asarray([5, 9], jax.numpy.int32))
    np.testing.assert_array_equal(np.asarray(out["k"]), np.asarray(lc["k"]))
    np.testing.assert_array_equal(np.asarray(out["pos"]),
                                  np.asarray(lc["pos"]))
