"""Federated runtime: server semantics, cohort training, e2e improvement."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core import lora
from repro.fed import (FedServer, ServerConfig, SimConfig, run_experiment,
                       split_adapters)
from repro.fed.simulation import pretrain_backbone
from repro.models import model as model_lib


@pytest.fixture(scope="module")
def cfg():
    return get_reduced("roberta-large")


@pytest.fixture(scope="module")
def base(cfg):
    sim = SimConfig(num_examples=1024, pretrain_steps=60, seed=0)
    return pretrain_backbone(cfg, sim)


def _server(cfg, base, **kw):
    scfg = ServerConfig(num_clients=10, clients_per_round=4, **kw)
    sizes = np.arange(1, 11) * 10
    return FedServer(cfg, scfg, base, client_sizes=sizes), scfg


def test_rank_assignment_policies(cfg, base):
    for policy in ("uniform", "random", "capacity", "data"):
        server, scfg = _server(cfg, base, rank_policy=policy, r_min=2, r_max=8)
        assert server.ranks.shape == (10,)
        assert server.ranks.min() >= 2 and server.ranks.max() <= 8
        if policy == "uniform":
            assert (server.ranks == 8).all()


def test_cohort_adapters_masked_to_rank(cfg, base):
    server, _ = _server(cfg, base, rank_policy="random", r_min=2, r_max=8)
    cohort = np.array([0, 3, 7])
    stacked = server.cohort_adapters(cohort)
    for t, ad in stacked.items():
        r_eff = np.asarray(jnp.sum(ad["mask"], axis=-1))
        for i, cid in enumerate(cohort):
            assert (r_eff[i] == server.ranks[cid]).all()
            # masked columns are exactly zero
            m = np.asarray(ad["mask"][i])
            a = np.asarray(ad["A"][i])
            assert np.all(a * (1 - m[..., None, :]) == 0)


def test_cohort_weights_proportional(cfg, base):
    server, _ = _server(cfg, base)
    cohort = np.array([0, 9])  # sizes 10 vs 100
    eta = np.asarray(server.cohort_weights(cohort))
    np.testing.assert_allclose(eta, [10 / 110, 100 / 110], rtol=1e-6)


def test_update_global_hlora_preserves_mean_update(cfg, base):
    """After update_global, the stored full-rank adapter's ΔW equals the
    exact FedAvg of the cohort's effective updates (rank permitting)."""
    server, _ = _server(cfg, base, strategy="hlora", rank_policy="uniform")
    cohort = np.array([1, 2, 5])
    stacked = server.cohort_adapters(cohort)
    key = jax.random.PRNGKey(3)
    # pretend clients trained: random B
    for t in stacked:
        stacked[t]["B"] = jax.random.normal(
            jax.random.fold_in(key, hash(t) % 100), stacked[t]["B"].shape) \
            * stacked[t]["mask"][..., :, None]
    from repro.core.aggregate import reconstruct_global_update
    eta = server.cohort_weights(cohort)
    alpha = cfg.lora.alpha
    server.update_global(stacked, cohort)
    for t, ad in server.global_lora.items():
        exact = reconstruct_global_update(stacked[t], eta, alpha)
        got = lora.delta_w(ad, alpha)
        np.testing.assert_allclose(np.asarray(got), np.asarray(exact),
                                   rtol=1e-3, atol=1e-4)


def test_e2e_experiment_runs_and_improves(cfg, base):
    sim = SimConfig(task="qqp", num_examples=1024, eval_examples=256,
                    rounds=3, local_steps=4, local_batch=8,
                    pretrain_steps=60, lr=1e-3, seed=0)
    scfg = ServerConfig(num_clients=8, clients_per_round=4,
                        strategy="hlora", rank_policy="random")
    h = run_experiment(cfg, sim, scfg, base_params=base)
    assert len(h["eval_acc"]) == 3
    assert all(np.isfinite(h["train_loss"]))
    assert h["eval_acc"][-1] > 0.5  # better than chance on easy task


def test_spectrum_rank_policy_adapts(cfg, base):
    """Beyond-paper: after aggregation the server tightens ranks to the
    smallest r capturing the configured share of ΔW' spectral energy."""
    server, _ = _server(cfg, base, strategy="hlora", rank_policy="spectrum",
                        r_min=2, r_max=8)
    assert (server.ranks == 8).all()  # starts at r_max
    cohort = np.array([0, 2, 4])
    stacked = server.cohort_adapters(cohort)
    key = jax.random.PRNGKey(11)
    for t in stacked:  # fake low-rank client updates (rank ~2 signal)
        b = stacked[t]["B"]
        u = jax.random.normal(jax.random.fold_in(key, hash(t) % 50),
                              (*b.shape[:-2], 2, b.shape[-1]))
        stacked[t]["B"] = jnp.concatenate(
            [u, jnp.zeros((*b.shape[:-2], b.shape[-2] - 2, b.shape[-1]))],
            axis=-2) * stacked[t]["mask"][..., :, None]
    server.update_global(stacked, cohort)
    # spectrum is rank-<=6 (3 clients x rank-2 signal) => ranks shrink
    assert server.ranks.max() <= 8
    assert (server.ranks == server.ranks[0]).all()
    assert server.ranks[0] <= 7, server.ranks[0]
