"""End-to-end behaviour of the full system (the paper's pipeline),
plus the benchmark harness's result-merge contract."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core import lora
from repro.fed import ServerConfig, SimConfig, run_centralized, run_experiment
from repro.fed.simulation import pretrain_backbone
from repro.models import model as model_lib


@pytest.fixture(scope="module")
def setup():
    cfg = get_reduced("roberta-large")
    sim = SimConfig(task="qqp", num_examples=1536, eval_examples=384,
                    rounds=4, local_steps=6, local_batch=16,
                    pretrain_steps=120, lr=1e-3, seed=0)
    base = pretrain_backbone(cfg, sim)
    return cfg, sim, base


def test_pipeline_all_strategies_finite(setup):
    cfg, sim, base = setup
    finals = {}
    for strat, policy in [("naive", "uniform"), ("hlora", "uniform"),
                          ("hlora", "random")]:
        scfg = ServerConfig(num_clients=8, clients_per_round=4,
                            strategy=strat, rank_policy=policy, seed=0)
        h = run_experiment(cfg, sim, scfg, base_params=base)
        assert np.isfinite(h["train_loss"]).all()
        assert np.isfinite(h["eval_acc"]).all()
        finals[f"{strat}/{policy}"] = h["eval_acc"][-1]
    # every strategy must at least beat chance after training on the easy task
    for k, v in finals.items():
        assert v > 0.5, (k, v)


def test_centralized_upper_bound_runs(setup):
    cfg, sim, base = setup
    h = run_centralized(cfg, sim, rank=8, base_params=base)
    assert h["eval_acc"][-1] > 0.5
    assert np.isfinite(h["train_loss"]).all()


def test_heterogeneous_comm_volume_less_than_homogeneous(setup):
    """Claim C4: HLoRA comm ∝ r_k — heterogeneous cohorts transmit less."""
    cfg, sim, base = setup
    from repro.fed.server import FedServer
    scfg_h = ServerConfig(num_clients=8, clients_per_round=8,
                          strategy="hlora", rank_policy="random",
                          r_min=2, r_max=8, seed=0)
    scfg_u = ServerConfig(num_clients=8, clients_per_round=8,
                          strategy="hlora", rank_policy="uniform",
                          r_max=8, seed=0)
    sizes = [64] * 8
    sv_h = FedServer(cfg, scfg_h, base, sizes)
    sv_u = FedServer(cfg, scfg_u, base, sizes)

    def total_bytes(server):
        tot = 0
        for cid in range(8):
            r = int(server.ranks[cid])
            for t, ad in server.global_lora.items():
                tot += lora.comm_bytes(ad, r)
        return tot

    assert total_bytes(sv_h) < total_bytes(sv_u)


def test_fed_lora_deployable_merge(setup):
    """Merged weights (deployment path) match adapter forward."""
    cfg, sim, base = setup
    params = model_lib.init_params(jax.random.PRNGKey(1), cfg)
    for t, ad in params["lora"].items():
        params["lora"][t]["B"] = jax.random.normal(
            jax.random.PRNGKey(hash(t) % 97), ad["B"].shape) * 0.02
    tokens = jax.random.randint(jax.random.PRNGKey(2), (2, 32), 0,
                                cfg.vocab_size)
    batch = {"tokens": tokens, "labels": jnp.zeros((2,), jnp.int32)}
    logits_adapter, _ = model_lib.forward(params, batch, cfg, remat=False,
                                          q_chunk=16)
    merged = jax.tree.map(lambda x: x, params)
    name_map = {"q": "wq", "v": "wv"}
    for t, ad in params["lora"].items():
        merged["layers"]["attn"][name_map[t]] = lora.merge(
            merged["layers"]["attn"][name_map[t]], ad, cfg.lora.alpha)
        merged["lora"][t] = dict(ad, B=jnp.zeros_like(ad["B"]))
    logits_merged, _ = model_lib.forward(merged, batch, cfg, remat=False,
                                         q_chunk=16)
    np.testing.assert_allclose(np.asarray(logits_adapter),
                               np.asarray(logits_merged),
                               rtol=2e-3, atol=2e-3)


def test_invariant_lint_full_tree_clean():
    """The invariant lint suite (repro.analysis) over the REAL tree:
    clock/RNG/hash/retrace/atomic-write discipline are wire contracts
    once edges run as separate processes — a violation anywhere in
    src/repro is a tier-1 failure at authoring time, not a flaky
    divergence at 10k clients. Sanctioned sites are pragma'd or
    allowlisted (see src/repro/analysis/README.md); everything else
    must be clean."""
    from repro.analysis import all_rules, run_paths
    root = os.path.join(os.path.dirname(__file__), os.pardir,
                        "src", "repro")
    assert len(all_rules()) >= 5
    findings = run_paths([root])
    assert findings == [], "\n".join(f.render() for f in findings)


def test_bench_quick_smoke_all_sections(tmp_path):
    """Tier-1 guard against benchmark rot: ``benchmarks.run --quick``
    must execute EVERY section end-to-end on tiny shapes and land a
    number for each in the results json. This is what catches an API
    drift in a benchmark script before it silently stops producing the
    paper's tables."""
    import sys
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    from benchmarks.run import ALL, main
    out = str(tmp_path / "bench.json")
    rc = main(["--quick", "--out", out,
               "--dryrun-jsonl", str(tmp_path / "missing.jsonl")])
    got = json.load(open(out))
    assert rc == 0, got.get("_errors")
    assert set(ALL) <= set(got), sorted(set(ALL) - set(got))
    # the speculative serving section reports the new metrics; the
    # exactness/acceptance asserts are deterministic — the speedup is
    # wall-clock on a noisy box, so only its presence is tier-1
    assert got["serve"]["spec_forced_exact"] == 1.0
    assert got["serve"]["spec_forced_acceptance"] == 1.0
    assert got["serve"]["spec_forced_speedup_vs_plain"] > 0
    # the mesh-scaling subsections run in forced-host-device children;
    # equivalence (bit-identity / byte-exactness vs single-device) is
    # deterministic and pinned — the speedups are wall-clock, presence
    # only
    assert got["fed"]["mesh_agg_bit_identical"] == 1
    assert got["fed"]["mesh_agg_speedup"] > 0
    assert got["serve"]["mesh_scaling_exact"] == 1.0
    assert got["serve"]["mesh_traces_flat"] == 1
    assert got["serve"]["mesh_tok_per_s_sharded"] > 0
    # the observability section: trace export validated, JSONL round-
    # tripped, and the promised span names present (all deterministic);
    # recorder-derived latency percentiles are wall-clock, presence only
    assert got["obs"]["obs_jsonl_roundtrip"] == 1
    assert got["obs"]["obs_span_names_ok"] == 1
    assert got["obs"]["obs_events"] > 0 and got["obs"]["obs_tracks"] > 0
    assert got["serve"]["obs_ttft_p99_ms"] > 0
    assert got["serve"]["obs_req_tok_s_p50"] > 0
    assert got["fed"]["obs_round_ms_p50"] > 0
    assert got["fed"]["obs_downlink_bytes_per_round"] > 0
    # the watching layer (PR 8): SLOs evaluate clean over the smoke
    # run, the HTML ops report renders non-empty, and the mesh child's
    # events were collected, clock-rebased, and merged into a trace
    # that validates
    assert got["obs"]["obs_slo_ok"] == 1
    assert got["obs"]["obs_series"] > 0
    assert got["obs"]["obs_report_bytes"] > 0
    assert got["obs"]["obs_child_events"] > 0
    assert got["obs"]["obs_merged_valid"] == 1
    assert got["obs"]["obs_merged_events"] > got["obs"]["obs_child_events"]
    # per-class TTFT SLO attainment (generous targets: deterministic)
    assert got["serve"]["obs_slo_interactive_attainment"] == 1.0
    assert got["serve"]["obs_slo_batch_attainment"] == 1.0
    assert got["serve"]["obs_slo_interactive_total"] > 0
    # per-round health snapshots rode along with the sync scheduler
    assert got["fed"]["obs_health_rounds"] > 0
    assert got["fed"]["obs_health_anomalies"] == 0.0
    # hierarchical two-tier aggregation: stack mode is pinned bit-identical
    # to flat, and the edge->root tier carries measured wire bytes
    assert got["fed"]["hier_bit_identical"] == 1
    assert got["fed"]["hier_edge_uplink_bytes_per_round"] > 0
    assert got["fed"]["hier_engine_edge_bytes_per_round"] > 0
    # population-scale round: lazy materialization never exceeds cohort
    assert got["fed"]["pop_clients"] >= 2000
    assert got["fed"]["pop_max_resident"] <= got["fed"]["pop_cohort"]
    assert got["fed"]["pop_uplink_bytes_per_round"] > 0
    # wire codec curve: none is exact, quantized/truncated curves are
    # strictly cheaper than raw f32 (deterministic byte counts)
    assert got["comm"]["codec_none_rel_err"] == 0.0
    assert got["comm"]["codec_int8_bytes"] < got["comm"]["codec_bf16_bytes"]
    assert got["comm"]["codec_bf16_bytes"] < got["comm"]["codec_none_bytes"]
    assert got["comm"]["codec_topk2_bytes"] < got["comm"]["codec_none_bytes"]
    # the invariant lint suite ran through its real CLI entry point:
    # the pass registry lists all >=5 rules and the shipped tree is
    # clean (both deterministic — a broken registry import or a new
    # un-pragma'd violation fails the smoke run here)
    assert got["analysis"]["rules_listed"] >= 5
    assert got["analysis"]["cli_list_rc"] == 0
    assert got["analysis"]["tree_clean"] == 1
    # every invocation appends to the perf history beside --out
    hist = str(tmp_path / "bench_history.jsonl")
    assert os.path.exists(hist)
    entries = [json.loads(l) for l in open(hist) if l.strip()]
    assert len(entries) == 1 and entries[0]["quick"] is True
    assert "serve.engine_tok_per_s" in entries[0]["results"]


def test_bench_merge_preserves_sections_on_failure(tmp_path):
    """A failing bench section must not clobber its previous good numbers
    (they stay, the error lands under '_errors'), a succeeding section
    clears its stale error, and untouched sections persist."""
    import sys
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    from benchmarks.run import merge_results
    path = str(tmp_path / "bench.json")
    merge_results(path, {"serve": {"x": 1}, "svd": {"y": 2}}, {})
    merge_results(path, {"svd": {"y": 3}}, {"serve": "RuntimeError: boom"})
    got = json.load(open(path))
    assert got["serve"] == {"x": 1}          # old numbers survive
    assert got["svd"] == {"y": 3}            # re-run section updated
    assert got["_errors"] == {"serve": "RuntimeError: boom"}
    merge_results(path, {"serve": {"x": 9}}, {})
    got = json.load(open(path))
    assert got["serve"] == {"x": 9} and "_errors" not in got
    # corrupt previous file: start fresh instead of crashing
    with open(path, "w") as f:
        f.write("{not json")
    merge_results(path, {"comm": {"z": 1}}, {})
    assert json.load(open(path)) == {"comm": {"z": 1}}


def test_bench_regression_gate(tmp_path):
    """The perf-regression gate at unit level: identical back-to-back
    runs pass, a >20% move in the bad direction on a curated key fails,
    a within-threshold move passes, and keys missing from either run
    are skipped (new benches don't break the gate)."""
    import sys
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    from benchmarks.run import (QUICK_REGRESSION_THRESHOLD,
                                REGRESSION_KEYS, REGRESSION_THRESHOLD,
                                append_history, check_regressions,
                                flatten_numeric, history_path_for)
    base = {"serve.engine_tok_per_s": 1000.0,
            "serve.obs_ttft_p99_ms": 10.0,
            "fed.obs_round_ms_p99": 200.0}
    # identical back-to-back: clean
    assert check_regressions(base, dict(base)) == []
    # within threshold (15% either way): clean
    ok = {"serve.engine_tok_per_s": 850.0,     # -15%, higher-is-better
          "serve.obs_ttft_p99_ms": 11.5,       # +15%, lower-is-better
          "fed.obs_round_ms_p99": 200.0}
    assert check_regressions(base, ok) == []
    # injected regressions: throughput -30%, latency +50%
    bad = {"serve.engine_tok_per_s": 700.0,
           "serve.obs_ttft_p99_ms": 15.0,
           "fed.obs_round_ms_p99": 200.0}
    hits = check_regressions(base, bad)
    assert {h[0] for h in hits} == {"serve.engine_tok_per_s",
                                    "serve.obs_ttft_p99_ms"}
    # IMPROVEMENTS never trip the gate (direction-aware)
    better = {"serve.engine_tok_per_s": 5000.0,
              "serve.obs_ttft_p99_ms": 1.0,
              "fed.obs_round_ms_p99": 50.0}
    assert check_regressions(base, better) == []
    # missing keys (either side) and zero/negative baselines: skipped
    assert check_regressions({}, bad) == []
    assert check_regressions({"serve.engine_tok_per_s": 0.0},
                             {"serve.engine_tok_per_s": 1.0}) == []
    # mesh keys are deliberately NOT gated (host-device artifacts)
    assert not any(k.startswith(("serve.mesh_", "fed.mesh_"))
                   for k in REGRESSION_KEYS)
    assert REGRESSION_THRESHOLD == pytest.approx(0.20)
    # quick smoke shapes jitter ~±30% wall-clock, so quick mode gates
    # wider — still far under the 2-10x moves a real perf rot produces
    assert QUICK_REGRESSION_THRESHOLD > REGRESSION_THRESHOLD
    bad30 = {"serve.engine_tok_per_s": 700.0}   # -30%: noise at --quick
    assert check_regressions(base, bad30,
                             threshold=QUICK_REGRESSION_THRESHOLD) == []
    bad60 = {"serve.engine_tok_per_s": 400.0}   # -60%: rot in any mode
    assert len(check_regressions(base, bad60,
                                 threshold=QUICK_REGRESSION_THRESHOLD)) == 1

    # flatten drops private keys, non-numerics, bools, non-dict
    # sections (roofline rows), and non-str keys (convergence sub-dicts
    # keyed by int rank)
    flat = flatten_numeric({"serve": {"a": 1, "_p": 2, "s": "x",
                                      "b": True},
                            "convergence": {4: {"acc": 0.9}, "n": 2},
                            "roofline": [{"gflops": 1.0}],
                            "_errors": {"x": "y"}})
    assert flat == {"serve.a": 1.0, "convergence.n": 2.0}

    # history: same-mode previous entry is returned, modes are disjoint
    hp = str(tmp_path / "h.jsonl")
    assert append_history(hp, {"k": 1.0}, quick=True) is None
    assert append_history(hp, {"k": 2.0}, quick=False) is None
    prev = append_history(hp, {"k": 3.0}, quick=True)
    assert prev["results"] == {"k": 1.0}
    assert len([l for l in open(hp) if l.strip()]) == 3
    # torn trailing line (crashed writer) is dropped, not fatal
    with open(hp, "a") as f:
        f.write("{torn")
    prev = append_history(hp, {"k": 4.0}, quick=True)
    assert prev["results"] == {"k": 3.0}

    assert history_path_for("results/bench_results.json") == \
        os.path.join("results", "bench_history.jsonl")
    assert history_path_for(str(tmp_path / "bench_quick.json")) == \
        str(tmp_path / "bench_quick_history.jsonl")


def test_bench_check_flag_fails_on_injected_regression(tmp_path):
    """--check end-to-end through main() without running real benches:
    seed the history with a strong previous entry, run only the cheap
    ``comm`` section, and verify rc. Since comm has no curated keys,
    the gate passes vacuously; then inject a history where the current
    run WOULD regress by pre-seeding overlapping keys via a fake
    section result written through append_history + check directly."""
    import sys
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    from benchmarks.run import check_regressions
    # the rc=2 path is main()'s only logic on top of check_regressions;
    # exercise the decision table here (running two full --quick passes
    # back-to-back in tier-1 would double suite time for no new signal)
    prev = {"serve.engine_tok_per_s": 1000.0}
    assert check_regressions(prev, {"serve.engine_tok_per_s": 799.0})
    assert not check_regressions(prev, {"serve.engine_tok_per_s": 801.0})
