"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attn import flash_attention as flash_raw
from repro.kernels.lora_matmul import lora_matmul as lora_raw
from repro.kernels.recon_agg import recon_agg as recon_raw

KEY = jax.random.PRNGKey(0)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("m,k,n,r", [(128, 128, 128, 128), (256, 512, 128, 128),
                                     (128, 256, 256, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_lora_matmul_sweep(m, k, n, r, dtype):
    ks = jax.random.split(KEY, 4)
    x = jax.random.normal(ks[0], (m, k), dtype)
    w0 = jax.random.normal(ks[1], (k, n), dtype)
    a = (jax.random.normal(ks[2], (k, r)) * 0.1).astype(dtype)
    b = (jax.random.normal(ks[3], (r, n)) * 0.1).astype(dtype)
    y = lora_raw(x, w0, a, b, 2.0, block_m=128, block_n=128, block_k=128,
                 interpret=True)
    yr = ref.lora_matmul_ref(x.astype(jnp.float32), w0.astype(jnp.float32),
                             a.astype(jnp.float32), b.astype(jnp.float32), 2.0)
    np.testing.assert_allclose(np.asarray(y, np.float32), yr, **_tol(dtype))


@pytest.mark.parametrize("kc,d,r,n", [(1, 128, 8, 128), (5, 256, 16, 128),
                                      (20, 128, 8, 256)])
def test_recon_agg_sweep(kc, d, r, n):
    ks = jax.random.split(KEY, 3)
    a = jax.random.normal(ks[0], (kc, d, r))
    b = jax.random.normal(ks[1], (kc, r, n))
    eta = jax.nn.softmax(jax.random.normal(ks[2], (kc,)))
    w = ops.recon_agg(a, b, eta, block_m=128, block_n=128, interpret=True)
    wr = ref.recon_agg_ref(a, b, eta)
    np.testing.assert_allclose(np.asarray(w), np.asarray(wr),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("sq,skv,h,d", [(128, 128, 2, 64), (128, 256, 4, 64),
                                        (256, 256, 2, 128)])
@pytest.mark.parametrize("window", [None, 64])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(sq, skv, h, d, window, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (sq, h, d), dtype)
    k = jax.random.normal(ks[1], (skv, h, d), dtype)
    v = jax.random.normal(ks[2], (skv, h, d), dtype)
    o = flash_raw(q, k, v, causal=True, window=window,
                  block_q=128, block_k=128, interpret=True)
    orf = ref.flash_attention_ref(q.astype(jnp.float32),
                                  k.astype(jnp.float32),
                                  v.astype(jnp.float32),
                                  causal=True, window=window)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(orf, np.float32), **_tol(dtype))


def test_flash_matches_model_attention():
    """The kernel agrees with the model's chunked-attention reference."""
    from repro.models.common import attention
    ks = jax.random.split(KEY, 3)
    b, s, h, d = 2, 128, 4, 32
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, h, d))
    v = jax.random.normal(ks[2], (b, s, h, d))
    o_kernel = ops.flash_attention(q, k, v, causal=True, window=64,
                                   block_q=64, block_k=64)
    o_model = attention(q, k, v, causal=True, window=64, q_chunk=64)
    np.testing.assert_allclose(np.asarray(o_kernel), np.asarray(o_model),
                               rtol=2e-4, atol=2e-4)


def test_flash_attention_per_row_q_offset():
    """ops.flash_attention with a (B,) q_offset gives every batch row its
    own absolute position — each row must match the single-row kernel at
    its scalar offset (the multi-row speculative-window contract)."""
    from repro.models.common import attention
    ks = jax.random.split(KEY, 3)
    b, sq, skv, h, d = 3, 8, 64, 2, 32
    q = jax.random.normal(ks[0], (b, sq, h, d))
    k = jax.random.normal(ks[1], (b, skv, h, d))
    v = jax.random.normal(ks[2], (b, skv, h, d))
    offs = np.asarray([0, 17, skv - sq], np.int32)
    got = ops.flash_attention(q, k, v, causal=True, q_offset=offs,
                              block_q=8, block_k=8)
    for i in range(b):
        want = attention(q[i:i + 1], k[i:i + 1], v[i:i + 1], causal=True,
                         q_offset=int(offs[i]))
        np.testing.assert_allclose(np.asarray(got[i:i + 1]),
                                   np.asarray(want), rtol=2e-4, atol=2e-4)


def test_ops_shape_padding_odd_lora_matmul():
    """Wrappers must pad non-MXU-aligned (M, K, N) and slice back — the
    raw kernel hard-asserts block divisibility (192 % 128 != 0 etc.)."""
    ks = jax.random.split(KEY, 4)
    m, k, n, r = 192, 384, 320, 8
    x = jax.random.normal(ks[0], (m, k))
    w0 = jax.random.normal(ks[1], (k, n))
    a = jax.random.normal(ks[2], (k, r)) * 0.1
    b = jax.random.normal(ks[3], (r, n)) * 0.1
    y = ops.lora_matmul(x, w0, a, b, 1.5, block_m=128, block_n=128,
                        block_k=128)
    assert y.shape == (m, n)
    yr = ref.lora_matmul_ref(x, w0, a, b, 1.5)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=2e-4, atol=2e-4)


def test_ops_shape_padding_odd_recon_agg():
    ks = jax.random.split(KEY, 3)
    kc, d_in, r, d_out = 5, 192, 8, 320
    a = jax.random.normal(ks[0], (kc, d_in, r))
    b = jax.random.normal(ks[1], (kc, r, d_out))
    eta = jax.nn.softmax(jax.random.normal(ks[2], (kc,)))
    w = ops.recon_agg(a, b, eta, block_m=128, block_n=128)
    assert w.shape == (d_in, d_out)
    wr = ref.recon_agg_ref(a, b, eta)
    np.testing.assert_allclose(np.asarray(w), np.asarray(wr),
                               rtol=2e-4, atol=2e-4)


def test_ops_rank_padding():
    """ops wrappers pad r<128 to lane width with zero extra contribution."""
    ks = jax.random.split(KEY, 4)
    x = jax.random.normal(ks[0], (128, 128))
    w0 = jax.random.normal(ks[1], (128, 128))
    a = jax.random.normal(ks[2], (128, 4)) * 0.1
    b = jax.random.normal(ks[3], (4, 128)) * 0.1
    y = ops.lora_matmul(x, w0, a, b, 1.5, block_m=128, block_n=128,
                        block_k=128)
    yr = ref.lora_matmul_ref(x, w0, a, b, 1.5)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=2e-4, atol=2e-4)
