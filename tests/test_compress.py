"""Wire codecs (fed/compress.py): round-trip error bounds, self-describing
decode, measured byte ordering, and session-level integration.

The codecs live *inside* the measured wire format, so every property here
is asserted on real serialized messages where it matters: ``num_bytes``
stays the length of the actual buffer, and a receiver decodes from the
header alone (no out-of-band codec configuration).
"""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import get_reduced
from repro.fed import (Bf16Codec, FedSession, Int8Codec, ServerConfig,
                       SimConfig, TopKCodec, codec_from_name, run_experiment)
from repro.fed import messages as msg_lib
from repro.fed.simulation import pretrain_backbone

ALPHA_SIM = SimConfig(task="mrpc", num_examples=512, eval_examples=128,
                      rounds=3, local_steps=2, local_batch=8,
                      pretrain_steps=20, lr=1e-3, seed=0)


@pytest.fixture(scope="module")
def cfg():
    return get_reduced("roberta-large")


@pytest.fixture(scope="module")
def base(cfg):
    return pretrain_backbone(cfg, ALPHA_SIM)


def _adapter(seed, layers=2, d_in=6, d_out=5, r=4):
    """A float32 payload with a spread of magnitudes per rank direction —
    the shape real truncated factors have on the wire."""
    rng = np.random.default_rng(seed)
    a = (rng.standard_normal((layers, d_in, r))
         * np.geomspace(1.0, 0.01, r)).astype(np.float32)
    b = (rng.standard_normal((layers, r, d_out))
         * np.geomspace(1.0, 0.01, r)[:, None]).astype(np.float32)
    return {"q": {"A": a, "B": b}, "v": {"A": 2 * a, "B": 0.5 * b}}


def _roundtrip(codec, adapter):
    arrays, meta = codec.encode_adapter(adapter)
    # meta must be JSON-safe: it rides in the wire header
    import json
    json.dumps(meta)
    return codec.decode_adapter(arrays, meta)


# ---------------------------------------------------------------------------
# Property tests: quantization error bounds / top-k exactness
# ---------------------------------------------------------------------------

@settings(max_examples=10)
@given(seed=st.integers(0, 1000), r=st.integers(1, 8),
       layers=st.integers(1, 3))
def test_int8_error_bounded_by_half_scale(seed, r, layers):
    adapter = _adapter(seed, layers=layers, r=r)
    codec = Int8Codec()
    arrays, meta = codec.encode_adapter(adapter)
    back = codec.decode_adapter(arrays, meta)
    for t, ad in adapter.items():
        for leaf in ("A", "B"):
            assert arrays[f"{t}/{leaf}"].dtype == np.int8
            scale = meta[t][f"{leaf}_scale"]
            err = np.abs(back[t][leaf] - ad[leaf])
            assert err.max() <= scale / 2 + 1e-7, (t, leaf)


@settings(max_examples=10)
@given(seed=st.integers(0, 1000), r=st.integers(1, 8))
def test_bf16_relative_error_bounded(seed, r):
    adapter = _adapter(seed, r=r)
    back = _roundtrip(Bf16Codec(), adapter)
    for t, ad in adapter.items():
        for leaf in ("A", "B"):
            err = np.abs(back[t][leaf] - ad[leaf])
            assert (err <= 2.0 ** -8 * np.abs(ad[leaf]) + 1e-12).all()


@settings(max_examples=10)
@given(seed=st.integers(0, 1000), r=st.integers(1, 8), k=st.integers(1, 10))
def test_topk_kept_directions_exact_dropped_zero(seed, r, k):
    adapter = _adapter(seed, r=r)
    codec = TopKCodec(k=k)
    arrays, meta = codec.encode_adapter(adapter)
    back = codec.decode_adapter(arrays, meta)
    for t, ad in adapter.items():
        keep = np.asarray(meta[t]["keep"], np.int64)
        assert len(keep) == min(k, r)
        assert (np.diff(keep) > 0).all() if len(keep) > 1 else True
        # kept columns cross the wire bit-exactly; dropped ones decode to
        # exact zeros (the truncate→pad invariant the session relies on)
        np.testing.assert_array_equal(back[t]["A"][..., keep],
                                      ad["A"][..., keep])
        np.testing.assert_array_equal(back[t]["B"][..., keep, :],
                                      ad["B"][..., keep, :])
        dropped = np.setdiff1d(np.arange(r), keep)
        assert not np.any(back[t]["A"][..., dropped])
        assert not np.any(back[t]["B"][..., dropped, :])
        if k >= r:    # full rank: the codec is lossless
            np.testing.assert_array_equal(back[t]["A"], ad["A"])
            np.testing.assert_array_equal(back[t]["B"], ad["B"])


def test_topk_keeps_highest_energy_directions():
    """With per-direction energies spanning orders of magnitude the kept
    set must be exactly the top-k by ‖A_j‖·‖B_j‖."""
    adapter = _adapter(7, r=8)
    a, b = adapter["q"]["A"], adapter["q"]["B"]
    score = (np.linalg.norm(a.reshape(-1, 8), axis=0)
             * np.linalg.norm(np.swapaxes(b, -2, -1).reshape(-1, 8), axis=0))
    _, meta = TopKCodec(k=3).encode_adapter({"q": adapter["q"]})
    want = np.sort(np.argsort(-score)[:3])
    np.testing.assert_array_equal(np.asarray(meta["q"]["keep"]), want)


# ---------------------------------------------------------------------------
# Wire integration: self-describing headers, measured bytes
# ---------------------------------------------------------------------------

def _update(codec, seed=0, r=8):
    return msg_lib.ClientUpdate(
        client_id=3, start_version=5, num_examples=64,
        adapter=_adapter(seed, layers=2, d_in=16, d_out=12, r=r),
        head={"cls": np.arange(6, dtype=np.float32)}, codec=codec)


def test_wire_self_describing_decode():
    """The receiver reconstructs from bytes alone — no codec object."""
    for codec, tol in ((Int8Codec(), 2e-2), (Bf16Codec(), 1e-2),
                       (TopKCodec(k=8), 0.0)):
        msg = _update(codec)
        back = msg_lib.ClientUpdate.from_bytes(msg.to_bytes())
        assert back.codec is None        # nothing but the header needed
        assert back.num_examples == 64 and back.start_version == 5
        for t, ad in msg.adapter.items():
            for leaf in ("A", "B"):
                got = np.asarray(back.adapter[t][leaf], np.float64)
                want = np.asarray(ad[leaf], np.float64)
                assert np.abs(got - want).max() <= \
                    tol * max(np.abs(want).max(), 1e-9) + 1e-12
        np.testing.assert_array_equal(back.head["cls"], msg.head["cls"])


def test_wire_bytes_ordering_and_none_identity():
    raw = _update(None)
    sizes = {name: _update(codec_from_name(name)).num_bytes
             for name in ("none", "int8", "bf16", "topk:2")}
    # codec=None is *byte-identical* to the codec-less format (golden-safe)
    assert sizes["none"] == raw.num_bytes
    assert _update(codec_from_name("none")).to_bytes() == raw.to_bytes()
    assert sizes["int8"] < sizes["bf16"] < sizes["none"]
    assert sizes["topk:2"] < sizes["none"]
    # every num_bytes is the real buffer length
    for name in sizes:
        m = _update(codec_from_name(name))
        assert m.num_bytes == len(m.to_bytes())


def test_codec_from_name_resolution():
    assert codec_from_name(None) is None
    assert codec_from_name("none") is None
    assert isinstance(codec_from_name("bf16"), Bf16Codec)
    assert isinstance(codec_from_name("int8"), Int8Codec)
    assert codec_from_name("topk").k == 4
    assert codec_from_name("topk:6").k == 6
    c = TopKCodec(k=2)
    assert codec_from_name(c) is c
    with pytest.raises(ValueError, match="unknown wire codec"):
        codec_from_name("zstd")
    with pytest.raises(ValueError, match="k >= 1"):
        TopKCodec(k=0)


# ---------------------------------------------------------------------------
# Session integration: codec applied to every message, bytes shrink
# ---------------------------------------------------------------------------

def test_topk_full_rank_session_broadcast_lossless(cfg, base):
    """topk at k=r_max through the session's wire path reconstructs the
    exact same cohort tree as the raw format."""
    scfg = ServerConfig(num_clients=4, clients_per_round=4,
                        strategy="hlora", rank_policy="random",
                        r_min=2, r_max=8, seed=0)
    sess_raw = FedSession(cfg, scfg, base, client_sizes=[64] * 4)
    sess_tk = FedSession(cfg, scfg, base, client_sizes=[64] * 4,
                         codec="topk:8")
    cohort = np.arange(4)
    tree_raw, _ = sess_raw.broadcast_cohort(cohort)
    tree_tk, _ = sess_tk.broadcast_cohort(cohort)
    for t in tree_raw:
        for leaf in ("A", "B", "mask"):
            np.testing.assert_array_equal(
                np.asarray(tree_tk[t][leaf]), np.asarray(tree_raw[t][leaf]),
                err_msg=(t, leaf))


def test_session_codec_shrinks_wire_and_trains(cfg, base):
    """ServerConfig.codec applies to every broadcast/update: int8 runs
    end-to-end to finite losses at ~4x less measured wire traffic."""
    sim = SimConfig(**{**ALPHA_SIM.__dict__, "rounds": 2})
    byts = {}
    for codec in ("none", "int8"):
        scfg = ServerConfig(num_clients=8, clients_per_round=4,
                            strategy="hlora", rank_policy="random",
                            r_min=2, r_max=8, seed=0, codec=codec)
        h = run_experiment(cfg, sim, scfg, base_params=base)
        assert np.isfinite(h["train_loss"]).all(), codec
        byts[codec] = (sum(h["downlink_bytes"]), sum(h["uplink_bytes"]))
    assert byts["int8"][0] < 0.6 * byts["none"][0]
    assert byts["int8"][1] < 0.6 * byts["none"][1]
